"""Benchmark timing utilities (CPU wall-clock of jit-compiled XLA code).

``--quick`` mode (``benchmarks.run --quick``, the CI smoke job) flips the
module-level ``QUICK`` flag: suites shrink to two tiny matrices and timing
loops to one iteration, so every benchmark entry point executes end-to-end
in seconds — rot protection, not measurement.
"""
from __future__ import annotations

import time

import jax
import numpy as np

#: smoke mode: tiny suites, single-iteration timing (set by benchmarks.run)
QUICK = False


def set_quick(on: bool = True) -> None:
    global QUICK
    QUICK = on


def pick_suite(full: bool = False) -> dict:
    """The R-MAT suite at the requested fidelity: paper-sized (``--full``),
    the reduced CI default, or two tiny matrices under ``--quick``."""
    from repro.core import rmat, rmat_suite, rmat_suite_small
    if QUICK:
        return {"tiny_uniform": rmat(5, 4, a=0.25, b=0.25, c=0.25, seed=0),
                "tiny_skewed": rmat(5, 4, seed=1)}
    return rmat_suite() if full else rmat_suite_small()


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call of an already-traceable fn(*args)."""
    if QUICK:
        warmup, iters = 1, 1
    jitted = jax.jit(fn) if not hasattr(fn, "lower") else fn
    out = jitted(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(jitted(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(xs))))


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def bytes_derived(flops: int, bytes_moved: int, seconds: float | None = None,
                  extra: str = "") -> str:
    """Derived-column text reporting modeled traffic next to wall time:
    bytes moved, arithmetic intensity (flops/byte), and — when a time is
    given — the implied effective bandwidth.  Kernel wins that are traffic
    wins show up here as AI movement even when wall time is interpret-mode
    noise."""
    parts = [f"bytes={bytes_moved}", f"ai={flops / max(bytes_moved, 1):.3f}"]
    if seconds is not None and seconds > 0:
        parts.append(f"gbps={bytes_moved / seconds / 1e9:.2f}")
    if extra:
        parts.append(extra)
    return "_".join(parts)
