"""Benchmark timing utilities (CPU wall-clock of jit-compiled XLA code)."""
from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median seconds per call of an already-traceable fn(*args)."""
    jitted = jax.jit(fn) if not hasattr(fn, "lower") else fn
    out = jitted(*args)
    jax.block_until_ready(out)
    for _ in range(warmup - 1):
        jax.block_until_ready(jitted(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def geomean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.mean(np.log(xs))))


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
