"""Fused SDDMM→SpMM chain benchmark (DESIGN.md §9): one-kernel fused chain
vs the unfused two-kernel pair, swept over R-MAT skew and dense width N.

Per (matrix, N) cell:

1. wall time of both executions (interpret-mode numbers off-TPU are
   correctness-grade; the modeled columns are the portable signal);
2. **modeled edge-value HBM bytes** (``repro.kernels.tune
   .modeled_traffic_chain``): the unfused pair pays the irreducible
   ``2·nnz·dtype`` round-trip (SDDMM writes every edge score, the SpMM's
   value stream reads it back) plus the softmax re-read; the fused kernel
   pays **zero** — scores are recomputed per column block and consumed in
   VMEM (the FusedMM trade);
3. max abs error of fused vs unfused — the fusion must be a pure
   traffic/scheduling change, not a numerics change;
4. the sharded chain (stacked per-shard visit schedules + cross-shard
   softmax merge) when more than one device is visible.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.api import sparse
from repro.core.selector import default_thresholds
from repro.kernels.tune import CHAIN_NEVER, modeled_traffic_chain
from . import common
from .common import bytes_derived, csv_row, geomean, pick_suite, time_fn

NS = (8, 128)
D = 32


def run(full: bool = False):
    suite = pick_suite(full)
    ns = (8,) if common.QUICK else NS
    d = 8 if common.QUICK else D
    rng = np.random.default_rng(0)
    th_fused = dataclasses.replace(default_thresholds(), chain_fuse_min_n=1)
    th_unfused = dataclasses.replace(default_thresholds(),
                                     chain_fuse_min_n=CHAIN_NEVER)
    rows, reductions = [], []
    for name, csr in suite.items():
        m, k = csr.shape
        a = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32) * 0.1)
        b = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32) * 0.1)
        Af = sparse(csr, backend="pallas", thresholds=th_fused,
                    chain_op="softmax", cache=False)
        Au = sparse(csr, backend="pallas", thresholds=th_unfused,
                    chain_op="softmax", cache=False)
        for n in ns:
            x = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
            traffic = modeled_traffic_chain(csr, n, d)
            t_fused = time_fn(lambda: Af.chain(a, b, x, transform="softmax"))
            t_unf = time_fn(lambda: Au.chain(a, b, x, transform="softmax"))
            err = float(np.abs(
                np.asarray(Af.chain(a, b, x, transform="softmax"))
                - np.asarray(Au.chain(a, b, x, transform="softmax"))).max())
            reductions.append(traffic["bytes_reduction"])
            rows.append(csv_row(
                f"sddmm_chain/{name}/n{n}/fused", t_fused * 1e6,
                bytes_derived(traffic["flops"], traffic["fused_bytes"],
                              t_fused,
                              f"edge_bytes={traffic['fused_edge_value_bytes']}"
                              f"_max_abs_err={err:.2e}")))
            rows.append(csv_row(
                f"sddmm_chain/{name}/n{n}/unfused", t_unf * 1e6,
                bytes_derived(traffic["flops"], traffic["unfused_bytes"],
                              t_unf,
                              f"edge_bytes="
                              f"{traffic['unfused_edge_value_bytes']}")))
            rows.append(csv_row(
                f"sddmm_chain/{name}/n{n}/edge_round_trip_eliminated", 0.0,
                f"{traffic['unfused_edge_value_bytes']}"))
    rows.append(csv_row("sddmm_chain/geomean_bytes_reduction", 0.0,
                        f"{geomean(reductions):.2f}"))

    if jax.device_count() > 1:
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()), ("shard",))
        name, csr = next(iter(suite.items()))
        m, k = csr.shape
        n = ns[-1]
        a = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32) * 0.1)
        b = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32) * 0.1)
        x = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
        As = sparse(csr, mesh=mesh, chain_op="softmax", cache=False)
        A1 = sparse(csr, backend="xla", chain_op="softmax", cache=False)
        traffic = modeled_traffic_chain(csr, n, d)
        t = time_fn(lambda: As.chain(a, b, x, transform="softmax"))
        err = float(np.abs(
            np.asarray(As.chain(a, b, x, transform="softmax"))
            - np.asarray(A1.chain(a, b, x, transform="softmax"))).max())
        rows.append(csv_row(
            f"sddmm_chain/{name}/n{n}/sharded{jax.device_count()}", t * 1e6,
            bytes_derived(traffic["flops"], traffic["fused_bytes"], t,
                          f"edge_bytes={traffic['fused_edge_value_bytes']}"
                          f"_max_abs_err={err:.2e}")))
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
