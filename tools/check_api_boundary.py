#!/usr/bin/env python
"""API-boundary lint: the plan/execute engine room is internal.

Everything outside ``src/repro/`` and ``tests/`` must go through the
``repro.api`` facade — direct imports of ``repro.core.plan`` (or of its
front-door names via ``repro.core``) or of ``repro.attention`` from
benchmarks, examples, tools, or docs snippets fail CI.  Run from the repo
root::

    python tools/check_api_boundary.py
"""
from __future__ import annotations

import pathlib
import re
import sys

#: directories whose code may reach into the engine room
ALLOWED_PREFIXES = ("src/repro/", "tests/")

#: imports that pierce the facade (``repro.attention`` is re-exported by
#: ``repro.api`` in full — external code never needs the subpackage itself)
BANNED = (
    re.compile(r"^\s*from\s+repro\.core\.plan\s+import\b"),
    re.compile(r"^\s*import\s+repro\.core\.plan\b"),
    re.compile(r"^\s*from\s+repro\.attention\b"),
    re.compile(r"^\s*import\s+repro\.attention\b"),
)


def check(root: pathlib.Path) -> list[str]:
    violations = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if rel.startswith(ALLOWED_PREFIXES) or "/." in f"/{rel}":
            continue
        text = path.read_text()
        for lineno, line in enumerate(text.splitlines(), 1):
            for pat in BANNED:
                if pat.match(line):
                    violations.append(f"{rel}:{lineno}: {line.strip()}")
        # "from repro.core import (...)" lists may span lines; scan the whole
        # parenthesized statement for the re-exported front-door names
        for m in re.finditer(
                r"from\s+repro\.core\s+import\s*(\([^)]*\)|[^\n]*)", text):
            names = re.split(r"[\s,()]+", m.group(1))
            bad = sorted({n for n in names if n in (
                "plan", "execute", "execute_pattern", "PlanBuilder",
                "SparsePlan")})
            if bad:
                lineno = text[:m.start()].count("\n") + 1
                violations.append(
                    f"{rel}:{lineno}: imports {', '.join(bad)} from "
                    "repro.core (use repro.api)")
    return violations


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    violations = check(root)
    if violations:
        print("API-boundary violations (use the repro.api facade):",
              file=sys.stderr)
        for v in violations:
            print(f"  {v}", file=sys.stderr)
        return 1
    print("api boundary clean: repro.core.plan and repro.attention stay "
          "inside src/repro and tests")
    return 0


if __name__ == "__main__":
    sys.exit(main())
