"""Quickstart: the paper's adaptive SpMV/SpMM library in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a skewed R-MAT matrix, wraps it in a first-class sparse operand
(``repro.sparse``: stats + Fig. 4 selector, plan cached by topology, kernel
substrates built lazily on first use), runs all four kernels of the 2x2
design space through ``A @ x`` / ``A.matmul``, cross-checks the Pallas
backend in interpret mode via the same door, and freezes a jit-safe
``PlanArtifact``."""
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

import repro
from repro.core import MATMUL_KERNELS


def main():
    # 1. a skewed sparse matrix (Graph500 R-MAT parameters)
    from repro.core import rmat
    csr = rmat(scale=10, edge_factor=16, seed=0)

    # 2. the first-class operand: statistics + thresholds once; the plan is
    #    cached by sparsity topology and substrates build lazily, only for
    #    the kernels that actually run (paper's offline/online split)
    A = repro.sparse(csr, tile=512)
    s = A.stats
    print(f"matrix: {A.shape}, nnz={A.nnz}, avg_row={s.avg_row:.1f}, "
          f"cv={s.cv:.2f} (skewed={s.skewed}); backend={A.backend}")
    rng = np.random.default_rng(0)

    # 3. the 2x2 space, SpMV and SpMM, all through the one operand
    for n in (1, 4, 64):
        x = jnp.asarray(rng.standard_normal((A.shape[1], n)).astype(np.float32))
        xv = x[:, 0] if n == 1 else x
        picked = A.plan.select(n)
        outs = {k: np.asarray(A.matmul(xv, impl=k)) for k in MATMUL_KERNELS}
        ref = outs["nb_pr"]
        agree = all(np.allclose(o, ref, atol=1e-3) for o in outs.values())
        print(f"N={n:3d}: rules pick {picked}; all four kernels agree: {agree} "
              f"(substrates built so far: {A.plan.built_substrates})")

    # 4. the Pallas TPU backend through the same front door (interpret mode
    #    on CPU = correctness harness) — just a different registry column
    x = jnp.asarray(rng.standard_normal((A.shape[1], 16)).astype(np.float32))
    ref = np.asarray(A.matmul(x, impl="nb_pr"))
    for k in ("nb_pr", "rs_sr"):
        y = np.asarray(A.matmul(x, impl=k, backend="pallas", interpret=True))
        print(f"pallas {k} maxerr: {np.abs(y - ref).max():.2e}")
    y1 = np.asarray(A.matmul(x[:, 0], impl="nb_pr", backend="pallas",
                             interpret=True))
    print(f"pallas spmv maxerr: {np.abs(y1 - ref[:, 0]).max():.2e}")

    # 5. value streams are live: same pattern + cached plan, new values —
    #    differentiable, so trainable sparse weights ride the same dispatch
    A2 = A.with_values(A.values * 2.0)
    print(f"live values: ||2A@x - 2(A@x)|| = "
          f"{np.abs(np.asarray(A2 @ x) - 2 * ref).max():.2e}")

    # 6. freeze to a jit-safe pytree artifact: passes through jit/scan as an
    #    argument, same compiled executable for equal-topology artifacts
    art = A.finalize(n=16)
    f = jax.jit(lambda a, xx: repro.api.execute(a, xx))
    y = np.asarray(f(art, x))
    print(f"PlanArtifact through jit maxerr: {np.abs(y - ref).max():.2e}")
    print(f"plan cache: {repro.cache_stats()}")


if __name__ == "__main__":
    main()
