"""Quickstart: the paper's adaptive SpMV/SpMM library in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a skewed R-MAT matrix, plans it once (stats + Fig. 4 selector; the
kernel substrate is built lazily on first execute), runs all four kernels of
the 2x2 design space through the one ``execute`` front door, and cross-checks
the Pallas backend in interpret mode via the same door."""
import sys

import numpy as np
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core import LOGICAL_KERNELS, execute, plan


def main():
    # 1. a skewed sparse matrix (Graph500 R-MAT parameters)
    from repro.core import rmat
    csr = rmat(scale=10, edge_factor=16, seed=0)

    # 2. offline plan: statistics + thresholds once; substrates built lazily,
    #    only for the kernels that actually run (paper's offline/online split)
    p = plan(csr, tile=512)
    s = p.stats
    print(f"matrix: {csr.shape}, nnz={csr.nnz}, avg_row={s.avg_row:.1f}, "
          f"cv={s.cv:.2f} (skewed={s.skewed}); backend={p.backend}")
    rng = np.random.default_rng(0)

    # 3. the 2x2 space, SpMV and SpMM, all through execute()
    for n in (1, 4, 64):
        x = jnp.asarray(rng.standard_normal((csr.shape[1], n)).astype(np.float32))
        xv = x[:, 0] if n == 1 else x
        picked = p.select(n)
        outs = {k: np.asarray(execute(p, xv, impl=k)) for k in LOGICAL_KERNELS}
        ref = outs["nb_pr"]
        agree = all(np.allclose(o, ref, atol=1e-3) for o in outs.values())
        print(f"N={n:3d}: rules pick {picked}; all four kernels agree: {agree} "
              f"(substrates built so far: {p.built_substrates})")

    # 4. the Pallas TPU backend through the same front door (interpret mode
    #    on CPU = correctness harness) — just a different registry column
    x = jnp.asarray(rng.standard_normal((csr.shape[1], 16)).astype(np.float32))
    ref = np.asarray(execute(p, x, impl="nb_pr"))
    for k in ("nb_pr", "rs_sr"):
        y = np.asarray(execute(p, x, impl=k, backend="pallas", interpret=True))
        print(f"pallas {k} maxerr: {np.abs(y - ref).max():.2e}")
    y1 = np.asarray(execute(p, x[:, 0], impl="nb_pr", backend="pallas",
                            interpret=True))
    print(f"pallas spmv maxerr: {np.abs(y1 - ref[:, 0]).max():.2e}")


if __name__ == "__main__":
    main()
