"""Quickstart: the paper's adaptive SpMV/SpMM library in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds a skewed R-MAT matrix, runs all four kernels of the 2x2 design space
(workload-balancing x reduction style), lets the paper's Fig.4 rules pick
one, and cross-checks the Pallas TPU kernels in interpret mode."""
import sys

import numpy as np
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.core import (KERNELS, PreparedMatrix, adaptive_spmm, matrix_stats,
                        rmat, select_kernel)
from repro.kernels import spmm_csc, spmm_vsr, spmv_vsr


def main():
    # 1. a skewed sparse matrix (Graph500 R-MAT parameters)
    csr = rmat(scale=10, edge_factor=16, seed=0)
    stats = matrix_stats(csr)
    print(f"matrix: {csr.shape}, nnz={csr.nnz}, avg_row={stats.avg_row:.1f}, "
          f"cv={stats.cv:.2f} (skewed={stats.skewed})")

    # 2. offline prep: both substrates + statistics (paper's usage mode)
    prep = PreparedMatrix.from_csr(csr, tile=512)
    rng = np.random.default_rng(0)

    # 3. the 2x2 space, SpMV and SpMM
    for n in (1, 4, 64):
        x = jnp.asarray(rng.standard_normal((csr.shape[1], n)).astype(np.float32))
        xv = x[:, 0] if n == 1 else x
        picked = select_kernel(stats, n)
        outs = {k: np.asarray(adaptive_spmm(prep, xv, impl=k)) for k in KERNELS}
        ref = outs["nb_pr"]
        agree = all(np.allclose(o, ref, atol=1e-3) for o in outs.values())
        print(f"N={n:3d}: rules pick {picked}; all four kernels agree: {agree}")

    # 4. the Pallas TPU kernels (interpret mode on CPU = correctness harness)
    x = jnp.asarray(rng.standard_normal((csr.shape[1], 16)).astype(np.float32))
    y_vsr = np.asarray(spmm_vsr(prep.balanced, x, interpret=True))
    y_csc = np.asarray(spmm_csc(prep.ell, x, interpret=True))
    y_spmv = np.asarray(spmv_vsr(prep.balanced, x[:, 0], interpret=True))
    ref = np.asarray(adaptive_spmm(prep, x, impl="nb_pr"))
    print(f"pallas vsr maxerr: {np.abs(y_vsr - ref).max():.2e}")
    print(f"pallas csc maxerr: {np.abs(y_csc - ref).max():.2e}")
    print(f"pallas spmv maxerr: {np.abs(y_spmv - ref[:, 0]).max():.2e}")


if __name__ == "__main__":
    main()
