"""Serving example: batched requests against a small MoE model whose expert
dispatch uses the paper's workload-balancing selection (sort-based row
binning vs one-hot, chosen by tokens-per-expert), plus topology-pinned
decoding: requests carrying a pinned expert topology decode through
dispatch plans cached per topology (``engine.plan_cache``) — repeated
routing patterns pay zero re-planning per tick.

    PYTHONPATH=src python examples/serve_moe.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_smoke
from repro.models import Model
from repro.serve import Request, ServeEngine


def main():
    cfg = get_smoke("olmoe-1b-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=3, max_len=64)

    prompts = [
        [1, 5, 9, 12],
        [3, 3, 7],
        [20, 21, 22, 23, 24],
        [11, 2],
        [8, 8, 8, 8],
    ]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new=8))
    done = engine.run_until_done()
    for r in done:
        print(f"req {r.rid}: prompt={r.prompt} → out={r.out} (done={r.done})")
    assert all(r.done for r in done)
    print(f"served {len(done)} requests in {engine.ticks} engine ticks "
          f"({len(prompts)} reqs on 3 slots → continuous batching)")

    # --- topology-pinned decode: the offline-plan/online-execute split -----
    engine2 = ServeEngine(model, params, slots=3, max_len=64)
    for i, p in enumerate(prompts):
        # pin each request to a (here: shared) expert pair; in production the
        # topology comes from prefill routing or a per-tenant profile
        engine2.submit(Request(rid=i, prompt=p, max_new=8, topology=(0, 3)))
    done2 = engine2.run_until_done()
    assert all(r.done for r in done2)
    s = engine2.plan_cache.stats()
    print(f"pinned decode: {engine2.ticks} ticks, dispatch plans built "
          f"{s['builds']}x, reused {s['hits']}x (topology-keyed PlanCache)")


if __name__ == "__main__":
    main()
