"""Serving example: batched requests against a small MoE model whose expert
dispatch uses the paper's workload-balancing selection (sort-based row
binning vs one-hot, chosen by tokens-per-expert), plus topology-pinned
decoding: requests carrying a pinned expert topology decode through
dispatch plans cached per topology (``engine.plan_cache``) — repeated
routing patterns pay zero re-planning per tick.

The hardening half (DESIGN.md §11): the engine's SLO telemetry
(``engine.metrics()``) and fault tolerance — a deterministic injected
plan-build failure degrades the affected request to the prep-free fallback
path while resident lanes keep producing, visible in the counters.

    PYTHONPATH=src python examples/serve_moe.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import get_smoke
from repro.models import Model
from repro.serve import FaultInjector, FaultSpec, Request, ServeEngine


def main():
    cfg = get_smoke("olmoe-1b-7b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=3, max_len=64)

    prompts = [
        [1, 5, 9, 12],
        [3, 3, 7],
        [20, 21, 22, 23, 24],
        [11, 2],
        [8, 8, 8, 8],
    ]
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new=8))
    done = engine.run_until_done()
    for r in done:
        print(f"req {r.rid}: prompt={r.prompt} → out={r.out} (done={r.done})")
    assert all(r.done for r in done)
    print(f"served {len(done)} requests in {engine.ticks} engine ticks "
          f"({len(prompts)} reqs on 3 slots → continuous batching)")

    # --- topology-pinned decode: the offline-plan/online-execute split -----
    engine2 = ServeEngine(model, params, slots=3, max_len=64)
    for i, p in enumerate(prompts):
        # pin each request to a (here: shared) expert pair; in production the
        # topology comes from prefill routing or a per-tenant profile
        engine2.submit(Request(rid=i, prompt=p, max_new=8, topology=(0, 3)))
    done2 = engine2.run_until_done()
    assert all(r.done for r in done2)
    s = engine2.plan_cache.stats()
    print(f"pinned decode: {engine2.ticks} ticks, dispatch plans built "
          f"{s['builds']}x, reused {s['hits']}x (topology-keyed PlanCache)")

    # --- SLO telemetry: what the engine measured about itself --------------
    m = engine2.metrics()
    t, lat = m["ticks"], m["latency"]
    print(f"telemetry: tick p50={t['p50_ms']:.2f}ms p99={t['p99_ms']:.2f}ms "
          f"occupancy={t['mean_occupancy']:.2f}  "
          f"ttft p50={lat['ttft_p50_ms']:.1f}ms "
          f"total p50={lat['total_p50_ms']:.1f}ms")
    engine.close()
    engine2.close()

    # --- fault tolerance: plan builds fail, serving does not ---------------
    faults = FaultInjector({"plan_build": FaultSpec(fail=10)}, seed=0)
    engine3 = ServeEngine(model, params, slots=3, max_len=64, faults=faults,
                          plan_timeout=0.5)
    for i, p in enumerate(prompts):
        engine3.submit(Request(rid=i, prompt=p, max_new=8, topology=(0, 3)))
    done3 = engine3.run_until_done()
    assert all(r.done for r in done3)   # every request still completed
    c = engine3.metrics()["counters"]
    print(f"faulted run: all {len(done3)} requests done via fallback — "
          f"plan_build_failures={c.get('plan_build_failures', 0)} "
          f"plan_retries={c.get('plan_retries', 0)} "
          f"fallback_lanes={c.get('plan_fallback_lanes', 0)}")
    engine3.close()


if __name__ == "__main__":
    main()
