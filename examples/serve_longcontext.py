"""Long-context serving example (DESIGN.md §10): batched requests against a
small transformer whose prefill attention runs through the **block-sparse
attention subsystem** — a causal sliding-window block mask compiled by the
pattern builders and executed as one fused sparse-softmax chain (SDDMM at
nonzero blocks → online masked softmax → SpMM against V, scores never
touching HBM).

The engine scopes attention plan builds into *its* ``PlanCache``
(``scoped_plan_cache``), so the mask artifact is built once and shared by
every layer, head, and same-shape request — the cache counters printed at
the end make that reuse observable.

    PYTHONPATH=src python examples/serve_longcontext.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SparseAttention, sliding_window
from repro.configs import get_smoke
from repro.models import Model
from repro.serve import Request, ServeEngine


def main():
    # a dense smoke config re-patterned for long context: causal sliding
    # window of 16 tokens on 8-token blocks → a 3-block causal band mask
    cfg = get_smoke("llama3.2-1b").scaled(
        attn_pattern="block_sparse", window=16, attn_block=8)
    assert cfg.sub_quadratic, "block_sparse must qualify for the long cells"
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, slots=3, max_len=64)

    # same-length prompts share one attention plan; the second length adds
    # exactly one more mask build — everything else is a cache hit
    prompts = [[(7 * i + j) % cfg.vocab_size for j in range(24)]
               for i in range(4)]
    prompts.append([(3 * j + 1) % cfg.vocab_size for j in range(40)])
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new=6))
    done = engine.run_until_done()
    for r in done:
        print(f"req {r.rid}: len(prompt)={len(r.prompt)} → out={r.out} "
              f"(done={r.done})")
    assert all(r.done for r in done)

    s = engine.plan_cache.stats()
    print(f"served {len(done)} requests in {engine.ticks} ticks on "
          f"{jax.device_count()} device(s)")
    print(f"attention plans: built {s['builds']}x for 2 distinct prompt "
          f"lengths (the scanned layer stack and every same-length request "
          f"share one traced plan lookup)")
    assert s["builds"] == 2, s

    # --- cross-layer sharing, made visible --------------------------------
    # Two standalone attention layers pointed at the engine's cache present
    # the same spec the 24-token prefills used (window=16 tok / block=8 →
    # 2-block causal band); nothing new is built — both calls are hits on
    # the plan the serving traffic already paid for.
    spec = sliding_window(24, 2, block=8, causal=True)
    layers = [SparseAttention(spec, cache=engine.plan_cache)
              for _ in range(2)]
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((24, cfg.head_dim)).astype("float32"))
    for layer in layers:
        jax.block_until_ready(layer(q, q, q))
    s = engine.plan_cache.stats()
    print(f"+2 standalone layers, same mask: built {s['builds']}x total, "
          f"reused {s['hits']}x — cross-layer/request sharing through one "
          f"PlanCache")
    assert s["builds"] == 2, s      # nothing new was built
    assert s["hits"] >= 2, s        # both layer calls hit the serving plan


if __name__ == "__main__":
    main()
