"""Train a tiny graph-attention layer with the fused SDDMM→SpMM chain.

    PYTHONPATH=src python examples/train_gat.py

A GAT-style layer over an R-MAT graph: project node features to queries
``Q = H Wq``, keys ``K = H Wk`` and values ``V = H Wv``, then one
``sparse_chain`` call computes masked-softmax attention over the graph's
edges and aggregates the values —

    y = softmax_rows(mask(Q @ K^T / sqrt(d))) @ V

On the Pallas backend the edge scores live only in VMEM: the SDDMM, the
row softmax and the aggregating SpMM run as one fused kernel (DESIGN.md
§9), so the ``O(nnz)`` attention stream never round-trips through HBM.
Gradients flow through both kernels of the chain — the backward is itself
an SDDMM+SpMM pair — so ``Wq``/``Wk``/``Wv`` all train with plain SGD.
"""
import sys

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro import api
from repro.core import rmat


def main():
    # 1. the graph: a skewed R-MAT adjacency (self-loops added so softmax
    #    rows are never empty), planned once and cached by topology
    csr = rmat(scale=9, edge_factor=8, seed=0)
    n_nodes = csr.shape[0]
    dense = np.zeros(csr.shape, np.float32)
    indptr = np.asarray(csr.indptr)
    cols = np.asarray(csr.indices)
    for i in range(n_nodes):
        dense[i, cols[indptr[i]:indptr[i + 1]]] = 1.0
        dense[i, i] = 1.0                              # self-loop
    A = api.sparse(dense, backend="pallas", chain_op="softmax")
    print(f"graph: {A.shape}, nnz={A.nnz}, backend={A.backend}")

    # 2. features + a 2-layer GAT head trained on a smooth regression target
    d_in, d_head = 32, 16
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((n_nodes, d_in)).astype(np.float32))
    target = jnp.asarray(
        rng.standard_normal((n_nodes, d_head)).astype(np.float32))
    params = {
        "wq": jnp.asarray(rng.standard_normal((d_in, d_head)) * 0.1,
                          jnp.float32),
        "wk": jnp.asarray(rng.standard_normal((d_in, d_head)) * 0.1,
                          jnp.float32),
        "wv": jnp.asarray(rng.standard_normal((d_in, d_head)) * 0.1,
                          jnp.float32),
    }
    alpha = 1.0 / np.sqrt(d_head)

    def forward(p):
        q, k, v = h @ p["wq"], h @ p["wk"], h @ p["wv"]
        # one call = SDDMM + masked row softmax + SpMM, fused on Pallas
        return A.chain(q, k, v, transform="softmax", alpha=alpha)

    def loss_fn(p):
        err = forward(p) - target
        return jnp.mean(err * err)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    # 3. plain SGD; the loss must drop and every projection must get grads
    lr = 0.5
    losses = []
    for step in range(20):
        loss, grads = grad_fn(params)
        losses.append(float(loss))
        gnorms = {k: float(jnp.linalg.norm(g)) for k, g in grads.items()}
        assert all(gn > 0 for gn in gnorms.values()), \
            f"a projection received zero gradient: {gnorms}"
        params = {k: w - lr * grads[k] for k, w in params.items()}
        if step % 5 == 0:
            print(f"step {step:2d}  loss={losses[-1]:.5f}  "
                  + "  ".join(f"|g_{k}|={v:.4f}" for k, v in gnorms.items()))
    assert losses[-1] < losses[0], (losses[0], losses[-1])
    print(f"loss {losses[0]:.5f} -> {losses[-1]:.5f} in {len(losses)} steps")

    # 4. cross-check the fused chain against the unfused XLA pair
    y_fused = forward(params)
    Au = api.sparse(dense, backend="xla", chain_op="softmax")
    q, k, v = (h @ params[w] for w in ("wq", "wk", "wv"))
    y_ref = Au.chain(q, k, v, transform="softmax", alpha=alpha)
    err = float(jnp.max(jnp.abs(y_fused - y_ref)))
    print(f"fused vs unfused max abs err: {err:.2e}")
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
