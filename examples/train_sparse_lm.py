"""End-to-end driver (deliverable b): train a ~100M-param llama-family model
for a few hundred steps on CPU, with the paper's sparse-FFN feature ON —
every MLP matmul runs through the adaptive SpMM with trainable nonzeros.

    PYTHONPATH=src python examples/train_sparse_lm.py --steps 200

Also demonstrates checkpoint/restart: kill it mid-run and rerun — it resumes
from the last committed step."""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.data import DataConfig, SyntheticLM
from repro.models import Model
from repro.models.config import SparseFFNConfig
from repro.runtime import DriverConfig, TrainDriver
from repro.train import OptConfig, TrainConfig, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--density", type=float, default=0.15)
    ap.add_argument("--sparse-backend", default=None,
                    help="pin the sparse kernels' backend for the whole step "
                         "(repro.api.use_backend scope; default: platform)")
    ap.add_argument("--calibrate-to", default=None,
                    help="background-calibrate selector thresholds to this "
                         "JSON on first run (auto-loads via $REPRO_THRESHOLDS)")
    args = ap.parse_args()

    cfg = get("llama3.2-1b").scaled(
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=4, d_ff=2048,
        vocab_size=8192, head_dim=64,
        sparse_ffn=SparseFFNConfig(density=args.density, tile=512),
        param_dtype="float32", compute_dtype="float32", remat="none")
    model = Model(cfg)
    from repro.models.params import param_count
    print(f"sparse-FFN LM: {param_count(model.specs)/1e6:.1f}M params "
          f"(FFN density {args.density})")

    tcfg = TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=20,
                                     total_steps=args.steps),
                       sparse_backend=args.sparse_backend)
    data = SyntheticLM(DataConfig(seed=0, vocab_size=cfg.vocab_size,
                                  seq_len=args.seq, global_batch=args.batch))
    step = jax.jit(make_train_step(model.loss_fn, tcfg), donate_argnums=(0,))
    state = init_state(model.init(jax.random.PRNGKey(0)), tcfg)

    driver = TrainDriver(
        DriverConfig(total_steps=args.steps, checkpoint_every=50,
                     checkpoint_dir="/tmp/repro_sparse_lm_ckpt",
                     calibrate_to=args.calibrate_to),
        step, lambda i: {k: jnp.asarray(v) for k, v in data.batch(i).items()})
    driver.run(state)
    losses = [e.metrics["loss"] for e in driver.events]
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f} over {len(losses)} steps")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
